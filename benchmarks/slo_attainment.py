"""Figure 5 + Figure 11: SLO attainment vs request rate, 3 LMMs x
{2,4,6,8} images/request, EPD vs DistServe vs vLLM.

``--gateway`` switches from the analytic simulator to LIVE serving: it
boots the real reduced engine behind the HTTP gateway and drives
sustained-QPS open-loop traffic (Poisson arrivals fired on schedule
whether or not earlier requests finished — the honest load model; a
closed loop self-throttles and hides queueing collapse). Each client
streams over SSE and measures TTFT/TPOT at the HTTP boundary, so the
attainment rows include gateway + scheduling + network overhead, not
just engine internals."""
from __future__ import annotations

import sys

if __package__ in (None, ""):
    # running as a script (python benchmarks/slo_attainment.py): put the
    # repo root and src/ on sys.path so `benchmarks.common` and `repro`
    # resolve without an external PYTHONPATH
    import os
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)

from repro.configs import get_config
from repro.core import A100_80G, SLO
from repro.core.cluster import ClusterSpec, simulate, summarize
from repro.data.workload import WorkloadSpec, poisson_requests

from benchmarks.common import (DIST_SPEC, EPD_SPEC, Row, SLO_TABLE9,
                               VLLM_SPEC, timed)

MODELS = ("minicpm-v-2.6", "internvl2-8b", "internvl2-26b")
SYSTEMS = {"EPD": (EPD_SPEC, True), "DistServe": (DIST_SPEC, False),
           "vLLM": (VLLM_SPEC, False)}


def run(quick: bool = False) -> list[Row]:
    rows: list[Row] = []
    images = (2, 4) if quick else (2, 4, 6, 8)
    rates = (0.25, 0.5) if quick else (0.1, 0.25, 0.5, 1.0)
    n_req = 40 if quick else 100
    for model in MODELS:
        cfg = get_config(model)
        for n_img in images:
            ttft_lim, tpot_lim = SLO_TABLE9[(model, n_img)]
            slo = SLO(ttft_lim, tpot_lim)
            for rate in rates:
                reqs = poisson_requests(cfg, WorkloadSpec(
                    rate=rate, n_requests=n_req, n_items=n_img,
                    output_len=10, slo=slo))
                for sysname, (spec, irp) in SYSTEMS.items():
                    out, us = timed(simulate, ClusterSpec(spec, irp=irp),
                                    cfg, A100_80G, reqs)
                    s = summarize(out, slo)
                    rows.append(Row(
                        f"fig5/{model}/img{n_img}/rate{rate}/{sysname}",
                        us, round(s.slo_attainment, 3),
                        {"ttft_mean": s.ttft_mean, "tpot_mean": s.tpot_mean}))
    return rows


# ------------------------------------------------- live gateway traffic
# SLO limits for the REDUCED model on CPU (the paper's Table 9 limits
# assume A100-class hardware); generous enough that an unloaded engine
# passes easily and a saturated one visibly does not.
GW_TTFT_LIMIT = 2.0      # seconds
GW_TPOT_LIMIT = 0.25     # seconds/token


def _drive_open_loop(gw, qps: float, n_req: int, max_tokens: int,
                     seed: int) -> list[dict]:
    """Fire ``n_req`` Poisson arrivals at ``qps`` against the gateway;
    each client streams over SSE and records HTTP-boundary timings."""
    import http.client
    import json
    import threading
    import time

    import numpy as np

    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / qps, n_req)
    results: list[dict] = [None] * n_req
    threads = []

    def client(i: int) -> None:
        t0 = time.perf_counter()
        rec = {"ok": False, "ttft": float("inf"), "tpot": float("inf"),
               "tokens": 0}
        try:
            c = http.client.HTTPConnection(gw.host, gw.port, timeout=300)
            c.request("POST", "/v1/chat/completions", body=json.dumps({
                "messages": [{"role": "user",
                              "content": f"open loop request {i}"}],
                "max_tokens": max_tokens, "stream": True}))
            r = c.getresponse()
            if r.status != 200:
                r.read()
                c.close()
                results[i] = rec
                return
            t_first = t_last = None
            buf = b""
            while True:
                chunk = r.read(64)
                if not chunk:
                    break
                buf += chunk
                while b"\n\n" in buf:
                    event, buf = buf.split(b"\n\n", 1)
                    data = event[len(b"data: "):]
                    if data == b"[DONE]" or not data:
                        continue
                    delta = json.loads(data)["choices"][0]["delta"]
                    if "content" in delta:
                        t_last = time.perf_counter()
                        if t_first is None:
                            t_first = t_last
                        rec["tokens"] += 1
            c.close()
            if t_first is not None:
                rec["ok"] = True
                rec["ttft"] = t_first - t0
                rec["tpot"] = ((t_last - t_first) / (rec["tokens"] - 1)
                               if rec["tokens"] > 1 else 0.0)
        except Exception:                                 # noqa: BLE001
            pass
        results[i] = rec

    for i in range(n_req):
        time.sleep(gaps[i])           # open loop: schedule is the clock
        t = threading.Thread(target=client, args=(i,), daemon=True)
        t.start()
        threads.append(t)
    for t in threads:
        t.join(timeout=300)
    return [r for r in results if r is not None]


def run_gateway(quick: bool = False) -> list[Row]:
    import jax
    import numpy as np

    from repro.models import build_model
    from repro.serving import EPDEngine, EngineConfig, GatewayServer

    cfg = get_config("pixtral-12b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    eng = EPDEngine(cfg, params, EngineConfig(
        n_encode_workers=2, decode_batch=8, kv_blocks=256))
    eng.start()
    gw = GatewayServer(eng, max_concurrent=16, max_queue=64).start()
    rows: list[Row] = []
    try:
        # one warmup completion so jit compiles don't land in row 1's TTFT
        _drive_open_loop(gw, qps=4.0, n_req=2, max_tokens=4, seed=0)
        rates = (2.0, 4.0) if quick else (2.0, 4.0, 8.0)
        n_req = 12 if quick else 40
        max_tokens = 8 if quick else 16
        for qps in rates:
            recs, us = timed(_drive_open_loop, gw, qps, n_req, max_tokens,
                             seed=int(qps * 10))
            ok = [r for r in recs if r["ok"]]
            met = [r for r in ok if r["ttft"] <= GW_TTFT_LIMIT
                   and r["tpot"] <= GW_TPOT_LIMIT]
            attainment = len(met) / max(len(recs), 1)
            ttfts = sorted(r["ttft"] for r in ok) or [float("inf")]
            tpots = [r["tpot"] for r in ok]
            rows.append(Row(
                f"gateway/qps{qps:g}", us, round(attainment, 3),
                {"n": len(recs), "completed": len(ok),
                 "ttft_p50": round(float(np.percentile(ttfts, 50)), 4),
                 "ttft_p95": round(float(np.percentile(ttfts, 95)), 4),
                 "tpot_mean": round(float(np.mean(tpots)), 4) if tpots
                 else None,
                 "ttft_limit": GW_TTFT_LIMIT, "tpot_limit": GW_TPOT_LIMIT}))
    finally:
        gw.stop()
        eng.stop()
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--gateway", action="store_true",
                    help="drive live open-loop HTTP traffic through the "
                         "serving gateway instead of the simulator")
    args = ap.parse_args()
    rows = run_gateway(args.quick) if args.gateway else run(args.quick)
    print("name,us_per_call,derived")
    for row in rows:
        print(f"{row.csv()},{row.extra}")
    if args.gateway:
        # a quick gateway run is a smoke gate: every request must at
        # least complete; attainment itself is the reported metric
        incomplete = [r for r in rows if r.extra["completed"] < r.extra["n"]]
        if incomplete:
            print(f"FAIL: {len(incomplete)} rate points had incomplete "
                  f"requests", file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
