"""Figure 5 + Figure 11: SLO attainment vs request rate, 3 LMMs x
{2,4,6,8} images/request, EPD vs DistServe vs vLLM."""
from __future__ import annotations

from repro.configs import get_config
from repro.core import A100_80G, SLO
from repro.core.cluster import ClusterSpec, simulate, summarize
from repro.data.workload import WorkloadSpec, poisson_requests

from benchmarks.common import (DIST_SPEC, EPD_SPEC, Row, SLO_TABLE9,
                               VLLM_SPEC, timed)

MODELS = ("minicpm-v-2.6", "internvl2-8b", "internvl2-26b")
SYSTEMS = {"EPD": (EPD_SPEC, True), "DistServe": (DIST_SPEC, False),
           "vLLM": (VLLM_SPEC, False)}


def run(quick: bool = False) -> list[Row]:
    rows: list[Row] = []
    images = (2, 4) if quick else (2, 4, 6, 8)
    rates = (0.25, 0.5) if quick else (0.1, 0.25, 0.5, 1.0)
    n_req = 40 if quick else 100
    for model in MODELS:
        cfg = get_config(model)
        for n_img in images:
            ttft_lim, tpot_lim = SLO_TABLE9[(model, n_img)]
            slo = SLO(ttft_lim, tpot_lim)
            for rate in rates:
                reqs = poisson_requests(cfg, WorkloadSpec(
                    rate=rate, n_requests=n_req, n_items=n_img,
                    output_len=10, slo=slo))
                for sysname, (spec, irp) in SYSTEMS.items():
                    out, us = timed(simulate, ClusterSpec(spec, irp=irp),
                                    cfg, A100_80G, reqs)
                    s = summarize(out, slo)
                    rows.append(Row(
                        f"fig5/{model}/img{n_img}/rate{rate}/{sysname}",
                        us, round(s.slo_attainment, 3),
                        {"ttft_mean": s.ttft_mean, "tpot_mean": s.tpot_mean}))
    return rows
