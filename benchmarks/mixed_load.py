"""Mixed-load scheduler benchmark: decode TPOT under a concurrent long
prefill (paper §4 SLO story).

Boots the real paged engine three ways on the same reduced model:

  clean      no long prefill          (the no-interference TPOT floor)
  chunked    prefill_chunk > 0        (continuous batching: the long
                                       prompt trickles in chunk-by-chunk
                                       between decode steps)
  unchunked  prefill_chunk = 0        (stall baseline: the whole prompt
                                       runs in one call and decode waits)

A batch of short decode requests streams tokens; once they are flowing,
one long-prompt request lands. Per-token wall-clock timestamps give the
inter-token gaps; the interference window is [long submit, long first
token]. Chunked scheduling keeps decode emitting inside that window with
a bounded worst gap (~ one chunk of prefill), while the unchunked row
shows the stall spike (max gap ~ the whole prefill).

    PYTHONPATH=src python benchmarks/mixed_load.py [--quick]
"""
from __future__ import annotations

if __package__ in (None, ""):
    import os
    import sys
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)

import threading
import time

import numpy as np

from benchmarks.common import Row

WALL_BOUND_S = 420.0       # --quick must finish inside this (CI smoke)


def _consume(handle, times: list, timeout: float) -> None:
    for _ in handle.stream(timeout=timeout):
        times.append(time.perf_counter())


def _gaps_overlapping(times: list, t0: float, t1: float) -> list:
    """Inter-token gaps that overlap the [t0, t1] window."""
    out = []
    for a, b in zip(times, times[1:]):
        if b >= t0 and a <= t1:
            out.append(b - a)
    return out


def mixed_load_stats(quick: bool = False, arch: str = "codeqwen1.5-7b",
                     chunk: int = 32) -> dict:
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import EPDEngine, EngineConfig, ServeRequest

    cfg = get_config(arch).reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    n_decoders = 3 if quick else 4
    max_new = 32 if quick else 48
    long_S = 240 if quick else 480
    max_seq = 256 if quick else 512
    short_prompts = [rng.integers(0, cfg.vocab, 16).astype(np.int32)
                     for _ in range(n_decoders)]
    long_prompt = rng.integers(0, cfg.vocab, long_S).astype(np.int32)

    out = {}
    for name, pchunk, with_long in (("clean", chunk, False),
                                    ("chunked", chunk, True),
                                    ("unchunked", 0, True)):
        eng = EPDEngine(cfg, params, EngineConfig(
            decode_batch=n_decoders + 1, kv_blocks=96, kv_block_size=16,
            max_seq_len=max_seq, prefill_chunk=pchunk))
        eng.start()
        # warm-up outside the window: compiles decode + the long-prompt
        # prefill variant (the unchunked path traces per prompt length)
        eng.submit(ServeRequest(req_id=900, prompt=long_prompt.copy(),
                                max_new_tokens=2)).result(timeout=600)
        eng.submit(ServeRequest(req_id=901,
                                prompt=short_prompts[0].copy(),
                                max_new_tokens=2)).result(timeout=600)

        handles, times = [], []
        for i, p in enumerate(short_prompts):
            h = eng.submit(ServeRequest(req_id=i + 1, prompt=p.copy(),
                                        max_new_tokens=max_new))
            ts: list = []
            threading.Thread(target=_consume, args=(h, ts, 600.0),
                             daemon=True).start()
            handles.append(h)
            times.append(ts)
        # let every decoder stream a few tokens before interference
        # (bounded: a dead consumer must fail the smoke, not hang it)
        ramp_deadline = time.perf_counter() + 120.0
        while any(len(ts) < 3 for ts in times):
            assert time.perf_counter() < ramp_deadline, \
                f"{name}: decoders never started streaming"
            time.sleep(0.005)

        t_long = t_long_first = None
        long_req = None
        if with_long:
            t_long = time.perf_counter()
            long_req = eng.submit(ServeRequest(
                req_id=500, prompt=long_prompt.copy(), max_new_tokens=4))
        results = [h.result(timeout=600) for h in handles]
        if with_long:
            lr = long_req.result(timeout=600)
            t_long_first = lr.t_first_token
        eng.stop()

        all_gaps = [g for ts in times for g in zip(ts, ts[1:])]
        all_gaps = [b - a for a, b in all_gaps]
        stats = {
            "finished": all(len(r.tokens) == max_new for r in results),
            "p95_gap_ms": float(np.percentile(all_gaps, 95)) * 1e3,
            "max_gap_ms": float(np.max(all_gaps)) * 1e3,
            "mean_tpot_ms": float(np.mean(all_gaps)) * 1e3,
            "prefill_chunks": eng.stats["prefill_chunks"],
        }
        if with_long:
            window = [g for ts in times
                      for g in _gaps_overlapping(ts, t_long, t_long_first)]
            in_window = sum(1 for ts in times for t in ts
                            if t_long <= t <= t_long_first)
            stats.update({
                "long_ttft_s": t_long_first - t_long,
                "decode_tokens_during_prefill": in_window,
                "window_p95_gap_ms": (float(np.percentile(window, 95)) * 1e3
                                      if window else float("nan")),
            })
        out[name] = stats
    return out


def stop_token_rows(arch: str = "codeqwen1.5-7b") -> list:
    """Acceptance: stop-token requests finish with finish_reason=="stop"
    in both modes (first run picks the stop id from a greedy reference)."""
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import (EPDEngine, EngineConfig, SamplingParams,
                               ServeRequest)

    cfg = get_config(arch).reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    prompt = np.random.default_rng(1).integers(0, cfg.vocab, 12) \
        .astype(np.int32)
    rows = []
    for mode in ("paged", "dense"):
        eng = EPDEngine(cfg, params, EngineConfig(
            decode_batch=2, kv_blocks=32, max_seq_len=64, mode=mode))
        eng.start()
        ref = eng.submit(ServeRequest(req_id=1, prompt=prompt.copy(),
                                      max_new_tokens=6)).result(timeout=600)
        stop = ref.tokens[3]
        out = eng.submit(ServeRequest(
            req_id=2, prompt=prompt.copy(), max_new_tokens=6,
            sampling=SamplingParams(stop_tokens=(stop,)))).result(timeout=600)
        eng.stop()
        assert out.finish_reason.value == "stop", (mode, out.finish_reason)
        rows.append(Row(f"mixed_load/stop_token/{mode}", 0.0,
                        out.finish_reason.value,
                        {"emitted": len(out.tokens),
                         "stopped_at": ref.tokens.index(stop)}))
    return rows


def run(quick: bool = False) -> list:
    t0 = time.perf_counter()
    s = mixed_load_stats(quick)
    clean, ch, un = s["clean"], s["chunked"], s["unchunked"]
    rows = [
        Row("mixed_load/clean", 0.0, round(clean["p95_gap_ms"], 2),
            {"mean_tpot_ms": round(clean["mean_tpot_ms"], 2),
             "max_gap_ms": round(clean["max_gap_ms"], 2)}),
        Row("mixed_load/chunked", 0.0, round(ch["p95_gap_ms"], 2),
            {"mean_tpot_ms": round(ch["mean_tpot_ms"], 2),
             "max_gap_ms": round(ch["max_gap_ms"], 2),
             "p95_ratio_vs_clean": round(
                 ch["p95_gap_ms"] / clean["p95_gap_ms"], 2),
             "decode_tokens_during_prefill":
                 ch["decode_tokens_during_prefill"],
             "long_ttft_s": round(ch["long_ttft_s"], 3),
             "prefill_chunks": ch["prefill_chunks"]}),
        Row("mixed_load/unchunked", 0.0, round(un["p95_gap_ms"], 2),
            {"mean_tpot_ms": round(un["mean_tpot_ms"], 2),
             "max_gap_ms": round(un["max_gap_ms"], 2),
             "p95_ratio_vs_clean": round(
                 un["p95_gap_ms"] / clean["p95_gap_ms"], 2),
             "decode_tokens_during_prefill":
                 un["decode_tokens_during_prefill"],
             "long_ttft_s": round(un["long_ttft_s"], 3),
             "stall_spike_vs_chunked_max_gap": round(
                 un["max_gap_ms"] / max(ch["max_gap_ms"], 1e-9), 2)}),
    ]
    rows.extend(stop_token_rows())
    wall = time.perf_counter() - t0

    # CI smoke assertions (the stall-spike magnitude is reported in the
    # rows, not asserted — wall-clock noise on shared CI boxes): every
    # request completed, decode kept emitting while the long prompt
    # chunk-prefilled (several tokens per chunk boundary, vs at most the
    # single pre-prefill iteration in the unchunked baseline), and the
    # quick run respects its wall-clock bound
    for name, st in s.items():
        assert st["finished"], f"{name}: decode requests did not finish"
    assert ch["decode_tokens_during_prefill"] >= 3, \
        "chunked scheduling failed to interleave decode with the prefill"
    assert (ch["decode_tokens_during_prefill"]
            > un["decode_tokens_during_prefill"]), \
        "chunked run should emit more decode tokens during the prefill " \
        "window than the unchunked stall baseline"
    if quick:
        assert wall < WALL_BOUND_S, f"mixed-load smoke too slow: {wall:.0f}s"
    rows.append(Row("mixed_load/wall_s", wall * 1e6, round(wall, 1)))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    for row in run(quick=args.quick):
        print(f"{row.name:44s} {row.derived!s:>10s}  {row.extra}")
