"""§4.3 + Table 2 + Table 3 + Table 8: memory savings of disaggregation.

Paper anchors: weight savings ~95%/96.2%/78.3% (E workers), Table 2
(images/request), Table 3 (max E/P batch), Table 8 (max KV %).
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core import A100_80G
from repro.core import costmodel as cm
from repro.core import memlimits as ml

from benchmarks.common import Row, timed

MODELS = ("minicpm-v-2.6", "internvl2-8b", "internvl2-26b")
RES = ((313, 234), (787, 444), (4032, 3024))
PAPER_WEIGHT_SAVING = {"minicpm-v-2.6": 0.95, "internvl2-8b": 0.962,
                       "internvl2-26b": 0.783}
PAPER_T2 = {  # (model, res) -> (DistServe, EPD)
    ("minicpm-v-2.6", (313, 234)): (77, 490),
    ("minicpm-v-2.6", (787, 444)): (26, 165),
    ("minicpm-v-2.6", (4032, 3024)): (7, 49),
    ("internvl2-8b", (313, 234)): (19, 19),
    ("internvl2-8b", (787, 444)): (19, 19),
    ("internvl2-8b", (4032, 3024)): (19, 19),
    ("internvl2-26b", (313, 234)): (1, 10),
    ("internvl2-26b", (787, 444)): (11, 45),
    ("internvl2-26b", (4032, 3024)): (1, 10),
}
PAPER_T8 = {  # (model, n_images) -> (DistServe, EPD)
    ("minicpm-v-2.6", 5): ("86", "99"), ("minicpm-v-2.6", 10): ("74", "97"),
    ("minicpm-v-2.6", 20): ("49", "95"), ("minicpm-v-2.6", 40): ("OOM", "92"),
    ("minicpm-v-2.6", 80): ("OOM", "OOCL"),
    ("internvl2-8b", 5): ("94", "95"), ("internvl2-8b", 10): ("89", "91"),
    ("internvl2-8b", 20): ("OOCL", "OOCL"),
    ("internvl2-26b", 5): ("67", "89"), ("internvl2-26b", 10): ("36", "80"),
    ("internvl2-26b", 20): ("OOM", "63"),
    ("internvl2-26b", 40): ("OOM", "OOCL"),
}


def run(quick: bool = False) -> list[Row]:
    rows: list[Row] = []
    for model in MODELS:
        cfg = get_config(model)
        # §4.3 weight savings at E workers
        full = cm.weights_bytes(cfg)
        enc = cm.weights_bytes(cfg, include_llm=False)
        rows.append(Row(f"sec4.3/{model}/e_weight_saving", 0.0,
                        round(1 - enc / full, 3),
                        {"paper": PAPER_WEIGHT_SAVING[model]}))
        # Table 2
        for res in RES:
            (d, _), us1 = timed(
                lambda: (ml.max_images_per_request(cfg, A100_80G, "EP", res),
                         None))
            e = ml.max_images_per_request(cfg, A100_80G, "E", res)
            paper = PAPER_T2[(model, res)]
            rows.append(Row(
                f"table2/{model}/{res[0]}x{res[1]}", us1,
                f"dist={d};epd={e}",
                {"paper_dist": paper[0], "paper_epd": paper[1]}))
        # Table 3 (10 images/request, E and P batch)
        for res in RES:
            dist = ml.max_batch(cfg, A100_80G, "EP", res, images_per_req=10)
            e = ml.max_batch(cfg, A100_80G, "E", res, images_per_req=10)
            p = ml.max_batch(cfg, A100_80G, "P", res, images_per_req=10)
            rows.append(Row(f"table3/{model}/{res[0]}x{res[1]}", 0.0,
                            f"dist={dist};epd_e={e};epd_p={p}"))
        # Table 8
        for n in (5, 10, 20, 40, 80):
            if (model, n) not in PAPER_T8:
                continue
            dist = ml.max_kv_percent(cfg, A100_80G, "EP", images_per_req=n)
            p = ml.max_kv_percent(cfg, A100_80G, "P", images_per_req=n)
            paper = PAPER_T8[(model, n)]
            rows.append(Row(f"table8/{model}/img{n}", 0.0,
                            f"dist={dist};epd={p}",
                            {"paper_dist": paper[0], "paper_epd": paper[1]}))
    return rows
