"""Kernel micro-benchmarks: per-call wall time of the jnp oracle path on
this host (the Pallas kernels themselves are TPU-targeted; interpret mode
is a correctness harness, not a performance proxy)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.decode_attn import decode_attn_ref
from repro.kernels.flash_prefill import flash_prefill_ref
from repro.kernels.mamba2_scan import mamba2_ssd_ref
from repro.kernels.rwkv6_scan import rwkv6_wkv_ref

from benchmarks.common import Row

KEY = jax.random.PRNGKey(0)


def _time(fn, *args, reps=5):
    out = jax.block_until_ready(fn(*args))            # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(quick: bool = False) -> list[Row]:
    rows = []
    ks = jax.random.split(KEY, 8)
    B, H, K, S, hd = 1, 8, 2, (256 if quick else 1024), 64
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, K, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, K, S, hd), jnp.float32)
    f = jax.jit(lambda a, b, c: flash_prefill_ref(a, b, c, causal=True))
    rows.append(Row(f"kernel/flash_prefill_ref/S{S}", _time(f, q, k, v),
                    "cpu_oracle"))

    W = 2048 if quick else 8192
    qd = jax.random.normal(ks[3], (4, H, hd), jnp.float32)
    kc = jax.random.normal(ks[4], (4, W, K, hd), jnp.float32)
    vc = jax.random.normal(ks[5], (4, W, K, hd), jnp.float32)
    ln = jnp.full((4,), W, jnp.int32)
    fd = jax.jit(decode_attn_ref)
    rows.append(Row(f"kernel/decode_attn_ref/W{W}", _time(fd, qd, kc, vc, ln),
                    "cpu_oracle"))

    Sm = 256 if quick else 1024
    x = jax.random.normal(ks[6], (1, Sm, 8, 64), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[7], (1, Sm, 8), jnp.float32))
    a = -dt * 0.5
    bm = jax.random.normal(ks[0], (1, Sm, 64), jnp.float32)
    cm_ = jax.random.normal(ks[1], (1, Sm, 64), jnp.float32)
    fm = jax.jit(lambda *t: mamba2_ssd_ref(*t, chunk=128)[0])
    rows.append(Row(f"kernel/mamba2_ssd_ref/S{Sm}",
                    _time(fm, x, dt, a, bm, cm_), "cpu_oracle"))

    r = jax.random.normal(ks[2], (1, Sm, 4, 64), jnp.float32)
    kk = jax.random.normal(ks[3], (1, Sm, 4, 64), jnp.float32)
    vv = jax.random.normal(ks[4], (1, Sm, 4, 64), jnp.float32)
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[5], (1, Sm, 4, 64)) * 0.3))
    u = jax.random.normal(ks[6], (4, 64), jnp.float32) * 0.3
    fr = jax.jit(lambda *t: rwkv6_wkv_ref(*t)[0])
    rows.append(Row(f"kernel/rwkv6_wkv_ref/S{Sm}",
                    _time(fr, r, kk, vv, w, u), "cpu_oracle"))
    return rows
