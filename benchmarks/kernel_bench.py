"""Kernel micro-benchmarks: per-call wall time of the jnp oracle path on
this host, PLUS kernel-vs-ref rows (the Pallas kernels in interpret mode
— a correctness harness, not a performance proxy off-TPU; the derived
column carries the max |kernel - ref| deviation so CI logs catch drift)
and a packed-runner vs two-program serving iteration row.

Runnable standalone (``python benchmarks/kernel_bench.py [--quick]``) or
through ``python -m benchmarks.run --only kernel_bench``.
"""
from __future__ import annotations

import time

if __name__ == "__main__":
    # standalone invocation: put the repo root and src/ on sys.path so
    # `benchmarks.common` and `repro` resolve
    import os
    import sys
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)

import jax
import jax.numpy as jnp

from repro.kernels.decode_attn import decode_attn, decode_attn_ref
from repro.kernels.flash_prefill import flash_prefill, flash_prefill_ref
from repro.kernels.mamba2_scan import mamba2_ssd_ref
from repro.kernels.paged_attn.kernel import paged_decode_attn
from repro.kernels.paged_attn.ref import paged_decode_attn_ref
from repro.kernels.rwkv6_scan import rwkv6_wkv_ref

from benchmarks.common import Row

KEY = jax.random.PRNGKey(0)


def _time(fn, *args, reps=5):
    out = jax.block_until_ready(fn(*args))            # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def _maxdiff(a, b) -> float:
    import numpy as np
    return float(np.abs(np.asarray(a, np.float32)
                        - np.asarray(b, np.float32)).max())


def run(quick: bool = False) -> list[Row]:
    rows = []
    ks = jax.random.split(KEY, 8)
    B, H, K, S, hd = 1, 8, 2, (256 if quick else 1024), 64
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, K, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, K, S, hd), jnp.float32)
    f = jax.jit(lambda a, b, c: flash_prefill_ref(a, b, c, causal=True))
    rows.append(Row(f"kernel/flash_prefill_ref/S{S}", _time(f, q, k, v),
                    "cpu_oracle"))
    # kernel vs ref: interpret mode off-TPU (compiled on TPU) at a
    # smaller S so the quick tier stays quick
    Sk_ = 128
    fk = jax.jit(lambda a, b, c: flash_prefill(
        a, b, c, causal=True, interpret=jax.default_backend() != "tpu"))
    qs, kss, vs = q[:, :, :Sk_], k[:, :, :Sk_], v[:, :, :Sk_]
    rows.append(Row(f"kernel/flash_prefill/S{Sk_}", _time(fk, qs, kss, vs, reps=2),
                    f"maxdiff={_maxdiff(fk(qs, kss, vs), f(qs, kss, vs)):.1e}"))

    W = 2048 if quick else 8192
    qd = jax.random.normal(ks[3], (4, H, hd), jnp.float32)
    kc = jax.random.normal(ks[4], (4, W, K, hd), jnp.float32)
    vc = jax.random.normal(ks[5], (4, W, K, hd), jnp.float32)
    ln = jnp.full((4,), W, jnp.int32)
    fd = jax.jit(decode_attn_ref)
    rows.append(Row(f"kernel/decode_attn_ref/W{W}", _time(fd, qd, kc, vc, ln),
                    "cpu_oracle"))
    Wk = 512
    fdk = jax.jit(lambda a, b, c, d: decode_attn(
        a, b, c, d, interpret=jax.default_backend() != "tpu"))
    kcs, vcs = kc[:, :Wk], vc[:, :Wk]
    lns = jnp.full((4,), Wk, jnp.int32)
    rows.append(Row(
        f"kernel/decode_attn/W{Wk}", _time(fdk, qd, kcs, vcs, lns, reps=2),
        f"maxdiff={_maxdiff(fdk(qd, kcs, vcs, lns), fd(qd, kcs, vcs, lns)):.1e}"))

    # paged decode: kernel (interpret) vs gather-oracle over one pool
    nb, bs, mb = 64, 16, 8
    kp = jax.random.normal(ks[6], (nb, bs, K, hd), jnp.float32)
    vp = jax.random.normal(ks[7], (nb, bs, K, hd), jnp.float32)
    tables = jax.random.randint(ks[0], (4, mb), 0, nb, jnp.int32)
    lens = jnp.asarray([mb * bs, 40, 17, 100], jnp.int32)
    fp_ref = jax.jit(paged_decode_attn_ref)
    rows.append(Row(f"kernel/paged_attn_ref/b{bs}x{mb}",
                    _time(fp_ref, qd, kp, vp, tables, lens), "cpu_oracle"))
    fpk = jax.jit(lambda a, b, c, d, e: paged_decode_attn(
        a, b, c, d, e, interpret=jax.default_backend() != "tpu"))
    rows.append(Row(
        f"kernel/paged_attn/b{bs}x{mb}",
        _time(fpk, qd, kp, vp, tables, lens, reps=2),
        f"maxdiff="
        f"{_maxdiff(fpk(qd, kp, vp, tables, lens), fp_ref(qd, kp, vp, tables, lens)):.1e}"))

    Sm = 256 if quick else 1024
    x = jax.random.normal(ks[6], (1, Sm, 8, 64), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[7], (1, Sm, 8), jnp.float32))
    a = -dt * 0.5
    bm = jax.random.normal(ks[0], (1, Sm, 64), jnp.float32)
    cm_ = jax.random.normal(ks[1], (1, Sm, 64), jnp.float32)
    fm = jax.jit(lambda *t: mamba2_ssd_ref(*t, chunk=128)[0])
    rows.append(Row(f"kernel/mamba2_ssd_ref/S{Sm}",
                    _time(fm, x, dt, a, bm, cm_), "cpu_oracle"))

    r = jax.random.normal(ks[2], (1, Sm, 4, 64), jnp.float32)
    kk = jax.random.normal(ks[3], (1, Sm, 4, 64), jnp.float32)
    vv = jax.random.normal(ks[4], (1, Sm, 4, 64), jnp.float32)
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[5], (1, Sm, 4, 64)) * 0.3))
    u = jax.random.normal(ks[6], (4, 64), jnp.float32) * 0.3
    fr = jax.jit(lambda *t: rwkv6_wkv_ref(*t)[0])
    rows.append(Row(f"kernel/rwkv6_wkv_ref/S{Sm}",
                    _time(fr, r, kk, vv, w, u), "cpu_oracle"))

    rows.extend(_runner_rows(quick))
    return rows


def _runner_rows(quick: bool) -> list[Row]:
    """Packed ModelRunner vs the two-program path: wall-clock of the SAME
    mixed workload (concurrent decode + chunked prefill) per runner. The
    derived column is the packed path's speedup (dispatches drop from
    1 + n_chunks to 1 per iteration; on CPU the margin is modest and
    noisy, so CI treats these as structural rows, not a gate)."""
    import numpy as np
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import EngineConfig, EPDEngine, ServeRequest

    cfg = get_config("codeqwen1.5-7b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    n_req = 3 if quick else 6
    prompts = [rng.integers(0, cfg.vocab, 70 + 16 * i).astype(np.int32)
               for i in range(n_req)]

    def serve(runner: str) -> tuple[float, int, dict]:
        eng = EPDEngine(cfg, params, EngineConfig(
            decode_batch=4, kv_blocks=128, max_seq_len=256,
            prefill_chunk=32, runner=runner))
        eng.start()
        try:
            for i, p in enumerate(prompts):   # warm the compile caches
                eng.submit(ServeRequest(req_id=i + 1, prompt=p.copy(),
                                        max_new_tokens=4))
            for i in range(n_req):
                eng.result(i + 1, timeout=300)
            steps0 = eng.stats["packed_steps"]
            t0 = time.perf_counter()
            for i, p in enumerate(prompts):
                eng.submit(ServeRequest(req_id=100 + i, prompt=p.copy(),
                                        max_new_tokens=8))
            for i in range(n_req):
                eng.result(100 + i, timeout=300)
            dt = time.perf_counter() - t0
            return dt, eng.stats["packed_steps"] - steps0, dict(eng.stats)
        finally:
            eng.stop()

    t_two, _, _ = serve("two_program")
    t_packed, timed_steps, stats = serve("packed")
    us = t_packed / max(1, timed_steps) * 1e6
    return [
        Row("runner/two_program/mixed_wall_s", t_two * 1e6,
            f"{t_two:.3f}s"),
        Row("runner/packed/mixed_wall_s", t_packed * 1e6,
            f"{t_packed:.3f}s speedup={t_two / max(t_packed, 1e-9):.2f}x"),
        Row("runner/packed/us_per_iteration", us,
            f"steps={timed_steps} "
            f"compiles={stats['packed_compiles']}"),
    ]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(quick=args.quick):
        print(row.csv(), flush=True)
