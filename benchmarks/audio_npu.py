"""Table 7 (audio modality, ultravox) + Figure 9 / Appendix F (NPU).

Table 7: 24 audio clips/request, 4 GPUs; vLLM DP vs DistServe 3P1D vs
EPD 2E1P1D; SLO TTFT<=2.0 TPOT<=0.025. Paper goodput: 1.01 / 0.45 / 1.16.

App F: encode-to-prefill latency ratio is 10-20% higher on 910B3 NPUs than
A100s, so EPD helps more there (Fig 9: EPD is the only system meeting the
8x4K-image SLO on NPUs).
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core import A100_80G, NPU_910B3, SLO
from repro.core import costmodel as cm
from repro.core.cluster import ClusterSpec, simulate, summarize
from repro.data.workload import WorkloadSpec, poisson_requests

from benchmarks.common import Row, timed

ULTRAVOX = get_config("ultravox-v0_3")
IVL8 = get_config("internvl2-8b")


def run_audio(quick: bool) -> list[Row]:
    slo = SLO(2.0, 0.025)
    rows = []
    n = 40 if quick else 100
    rates = (0.25, 1.0) if quick else (0.10, 0.25, 0.50, 1.00, 1.10, 1.15)
    systems = {"vLLM": ClusterSpec("4EPD", irp=False),
               "DistServe": ClusterSpec("3EP1D", irp=False),
               "EPD": ClusterSpec("2E1P1D", irp=True)}
    for rate in rates:
        reqs = poisson_requests(ULTRAVOX, WorkloadSpec(
            rate=rate, n_requests=n, n_items=24, output_len=10, slo=slo))
        for name, spec in systems.items():
            out, us = timed(simulate, spec, ULTRAVOX, A100_80G, reqs)
            s = summarize(out, slo)
            rows.append(Row(f"table7/rate{rate}/{name}", us,
                            round(s.slo_attainment, 3)))
    return rows


def run_npu(quick: bool) -> list[Row]:
    rows = []
    # Fig 12: encode/prefill latency ratio GPU vs NPU
    for n_img in (2, 4, 8):
        patches = n_img * IVL8.modality.patches_at_res[(4032, 3024)]
        seq = patches * IVL8.modality.tokens_per_item + 22
        r_gpu = cm.encode_time(IVL8, A100_80G, patches) / \
            cm.prefill_time(IVL8, A100_80G, seq)
        r_npu = cm.encode_time(IVL8, NPU_910B3, patches) / \
            cm.prefill_time(IVL8, NPU_910B3, seq)
        rows.append(Row(f"fig12/img{n_img}/enc_prefill_ratio", 0.0,
                        f"gpu={r_gpu:.2f};npu={r_npu:.2f}",
                        {"npu_vs_gpu": round(r_npu / r_gpu, 3),
                         "paper": "1.10-1.20"}))
    # Fig 9: NPU SLO attainment, 8x4K images, 5E2P1D optimum
    slo = SLO(8.5, 0.12)
    n = 30 if quick else 100
    for rate in ((0.05, 0.1) if quick else (0.05, 0.1, 0.2, 0.4)):
        reqs = poisson_requests(IVL8, WorkloadSpec(
            rate=rate, n_requests=n, n_items=8, output_len=10, slo=slo))
        for name, spec in (("EPD-NPU", ClusterSpec("5E2P1D", irp=True)),
                           ("vLLM-NPU", ClusterSpec("8EPD", irp=False)),
                           ("Dist-NPU", ClusterSpec("7EP1D", irp=False))):
            out, us = timed(simulate, spec, IVL8, NPU_910B3, reqs)
            s = summarize(out, slo)
            rows.append(Row(f"fig9/rate{rate}/{name}", us,
                            round(s.slo_attainment, 3)))
    return rows


def run(quick: bool = False) -> list[Row]:
    return run_audio(quick) + run_npu(quick)
