"""Shared benchmark plumbing: timing, CSV rows, paper-value annotations."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable


@dataclass
class Row:
    name: str
    us_per_call: float            # wall-clock of the measured operation, µs
    derived: Any                  # the headline metric for the paper table
    extra: dict = field(default_factory=dict)

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


# Paper SLO criteria (Table 9)
SLO_TABLE9 = {
    ("minicpm-v-2.6", 2): (1.40, 0.04), ("minicpm-v-2.6", 4): (2.60, 0.04),
    ("minicpm-v-2.6", 6): (3.90, 0.06), ("minicpm-v-2.6", 8): (5.10, 0.06),
    ("internvl2-8b", 2): (1.20, 0.05), ("internvl2-8b", 4): (2.40, 0.06),
    ("internvl2-8b", 6): (3.55, 0.09), ("internvl2-8b", 8): (5.00, 0.18),
    ("internvl2-26b", 2): (3.50, 0.07), ("internvl2-26b", 4): (7.05, 0.08),
    ("internvl2-26b", 6): (11.00, 0.95), ("internvl2-26b", 8): (15.00, 0.15),
}

EPD_SPEC = "5E2P1D"
DIST_SPEC = "7EP1D"
VLLM_SPEC = "8EPD"
