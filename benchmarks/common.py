"""Shared benchmark plumbing: timing, CSV rows, paper-value annotations."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable


@dataclass
class Row:
    name: str
    us_per_call: float            # wall-clock of the measured operation, µs
    derived: Any                  # the headline metric for the paper table
    extra: dict = field(default_factory=dict)

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


_ENGINE_MODE_CACHE: dict = {}
_ENGINE_MM_CACHE: dict = {}


def engine_mode_stats(quick: bool = False, arch: str = "pixtral-12b") -> dict:
    """Boot the REAL EPD engine twice on the same reduced model + workload —
    paged-batched decode vs the seed dense per-request loop — and measure
    decode tokens/s and peak KV-cache bytes. Requests go through the
    OpenAI-shaped frontend (parse -> submit -> chat.completion response),
    never poking request internals. Memoized so ttft and
    offline_throughput share one run per harness invocation."""
    key = (quick, arch)
    if key in _ENGINE_MODE_CACHE:
        return _ENGINE_MODE_CACHE[key]
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import EPDEngine, EngineConfig
    from repro.serving.api import build_chat_response, parse_chat_request

    cfg = get_config(arch).reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    n_req = 4 if quick else 8
    # decode-heavy so both modes hold decode_batch concurrent requests at
    # peak — the paged pool allocates blocks on demand while the dense mode
    # pads every per-request cache to S + max_new + headroom
    max_new = 16

    def payload(i: int) -> dict:
        text = " ".join(f"req{i}tok{j}" for j in range(16))
        return {"messages": [{"role": "user", "content": text}],
                "max_tokens": max_new}

    out = {}
    for mode in ("paged", "dense"):
        eng = EPDEngine(cfg, params, EngineConfig(
            n_encode_workers=2, max_new_tokens=max_new, decode_batch=4,
            mode=mode, kv_blocks=128, max_seq_len=128))
        eng.start()
        # warm-up request: compile prefill/decode outside the measured window
        eng.submit(parse_chat_request(cfg, payload(0))).result(timeout=600)
        eng.stats.update(decode_tokens=0, decode_steps=0, decode_time=0.0,
                         peak_cache_bytes=0)
        t0 = time.perf_counter()
        handles = [eng.submit(parse_chat_request(cfg, payload(i)))
                   for i in range(1, n_req + 1)]
        resps = [build_chat_response(cfg, h.result(timeout=600))
                 for h in handles]
        wall = time.perf_counter() - t0
        eng.stop()
        s = eng.stats
        out[mode] = {
            "decode_tok_s": s["decode_tokens"] / max(s["decode_time"], 1e-9),
            "decode_steps": s["decode_steps"],
            "peak_cache_bytes": s["peak_cache_bytes"],
            "mean_ttft": float(np.mean([r["timings"]["ttft"]
                                        for r in resps])),
            "wall_s": wall,
            "n_requests": n_req,
        }
    _ENGINE_MODE_CACHE[key] = out
    return out


def engine_mm_cache_stats(quick: bool = False,
                          arch: str = "pixtral-12b") -> dict:
    """ψ_EP multimedia-token cache (paper §3.2.1): TTFT of a first-seen
    multimodal payload vs a byte-identical repeat. On the repeat the
    engine serves the merged mm tokens from the content-hash-keyed cache
    and the E stage runs zero shards, so TTFT drops to queue + prefill."""
    key = (quick, arch)
    if key in _ENGINE_MM_CACHE:
        return _ENGINE_MM_CACHE[key]
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import EPDEngine, EngineConfig
    from repro.serving.api import chat_completion

    cfg = get_config(arch).reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    m = cfg.modality
    n_groups = 2 if quick else 4                 # image patch groups
    text = " ".join(f"w{j}" for j in range(n_groups * m.tokens_per_item + 8))

    def payload(image_seed: int) -> dict:
        rng = np.random.default_rng(image_seed)
        emb = (rng.standard_normal((n_groups * m.tokens_per_item,
                                    m.enc_d_model))
               .astype(np.float32) * 0.1)
        return {"messages": [{"role": "user", "content": [
                    {"type": "text", "text": text},
                    {"type": "image_embedding", "embedding": emb.tolist()}]}],
                "max_tokens": 4}

    eng = EPDEngine(cfg, params, EngineConfig(
        n_encode_workers=2, decode_batch=4, kv_blocks=128, max_seq_len=256))
    eng.start()
    # warm-up on a DIFFERENT image: compiles E/P/D outside the window
    chat_completion(eng, payload(0), timeout=600)
    first = chat_completion(eng, payload(1), timeout=600)
    shards_first_seen = eng.encode_stage.shards_run
    repeat = chat_completion(eng, payload(1), timeout=600)
    eng.stop()
    out = {
        "ttft_first": first["timings"]["ttft"],
        "ttft_repeat": repeat["timings"]["ttft"],
        "repeat_hit": repeat["timings"]["mm_cache_hit"],
        "cache_hits": eng.mm_cache.hits,
        "cache_misses": eng.mm_cache.misses,
        "encode_shards_after_repeat": eng.encode_stage.shards_run,
        "encode_shards_first_seen": shards_first_seen,
    }
    _ENGINE_MM_CACHE[key] = out
    return out


# Paper SLO criteria (Table 9)
SLO_TABLE9 = {
    ("minicpm-v-2.6", 2): (1.40, 0.04), ("minicpm-v-2.6", 4): (2.60, 0.04),
    ("minicpm-v-2.6", 6): (3.90, 0.06), ("minicpm-v-2.6", 8): (5.10, 0.06),
    ("internvl2-8b", 2): (1.20, 0.05), ("internvl2-8b", 4): (2.40, 0.06),
    ("internvl2-8b", 6): (3.55, 0.09), ("internvl2-8b", 8): (5.00, 0.18),
    ("internvl2-26b", 2): (3.50, 0.07), ("internvl2-26b", 4): (7.05, 0.08),
    ("internvl2-26b", 6): (11.00, 0.95), ("internvl2-26b", 8): (15.00, 0.15),
}

EPD_SPEC = "5E2P1D"
DIST_SPEC = "7EP1D"
VLLM_SPEC = "8EPD"
