"""Shared benchmark plumbing: timing, CSV rows, paper-value annotations."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable


@dataclass
class Row:
    name: str
    us_per_call: float            # wall-clock of the measured operation, µs
    derived: Any                  # the headline metric for the paper table
    extra: dict = field(default_factory=dict)

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


_ENGINE_MODE_CACHE: dict = {}
_ENGINE_MM_CACHE: dict = {}
_ENGINE_PREFIX_CACHE: dict = {}


def engine_mode_stats(quick: bool = False, arch: str = "pixtral-12b") -> dict:
    """Boot the REAL EPD engine twice on the same reduced model + workload —
    paged-batched decode vs the seed dense per-request loop — and measure
    decode tokens/s and peak KV-cache bytes. Requests go through the
    OpenAI-shaped frontend (parse -> submit -> chat.completion response),
    never poking request internals. Memoized so ttft and
    offline_throughput share one run per harness invocation."""
    key = (quick, arch)
    if key in _ENGINE_MODE_CACHE:
        return _ENGINE_MODE_CACHE[key]
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import EPDEngine, EngineConfig
    from repro.serving.api import build_chat_response, parse_chat_request

    cfg = get_config(arch).reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    n_req = 4 if quick else 8
    # decode-heavy so both modes hold decode_batch concurrent requests at
    # peak — the paged pool allocates blocks on demand while the dense mode
    # pads every per-request cache to S + max_new + headroom
    max_new = 16

    def payload(i: int) -> dict:
        text = " ".join(f"req{i}tok{j}" for j in range(16))
        return {"messages": [{"role": "user", "content": text}],
                "max_tokens": max_new}

    out = {}
    for mode in ("paged", "dense"):
        eng = EPDEngine(cfg, params, EngineConfig(
            n_encode_workers=2, max_new_tokens=max_new, decode_batch=4,
            mode=mode, kv_blocks=128, max_seq_len=128))
        eng.start()
        # warm-up request: compile prefill/decode outside the measured window
        eng.submit(parse_chat_request(cfg, payload(0))).result(timeout=600)
        eng.stats.update(decode_tokens=0, decode_steps=0, decode_time=0.0,
                         peak_cache_bytes=0)
        t0 = time.perf_counter()
        handles = [eng.submit(parse_chat_request(cfg, payload(i)))
                   for i in range(1, n_req + 1)]
        resps = [build_chat_response(cfg, h.result(timeout=600))
                 for h in handles]
        wall = time.perf_counter() - t0
        eng.stop()
        s = eng.stats
        out[mode] = {
            "decode_tok_s": s["decode_tokens"] / max(s["decode_time"], 1e-9),
            "decode_steps": s["decode_steps"],
            "peak_cache_bytes": s["peak_cache_bytes"],
            "mean_ttft": float(np.mean([r["timings"]["ttft"]
                                        for r in resps])),
            "wall_s": wall,
            "n_requests": n_req,
        }
    _ENGINE_MODE_CACHE[key] = out
    return out


def engine_mm_cache_stats(quick: bool = False,
                          arch: str = "pixtral-12b") -> dict:
    """ψ_EP multimedia-token cache (paper §3.2.1): TTFT of a first-seen
    multimodal payload vs a byte-identical repeat. On the repeat the
    engine serves the merged mm tokens from the content-hash-keyed cache
    and the E stage runs zero shards, so TTFT drops to queue + prefill."""
    key = (quick, arch)
    if key in _ENGINE_MM_CACHE:
        return _ENGINE_MM_CACHE[key]
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import EPDEngine, EngineConfig
    from repro.serving.api import chat_completion

    cfg = get_config(arch).reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    m = cfg.modality
    n_groups = 2 if quick else 4                 # image patch groups
    text = " ".join(f"w{j}" for j in range(n_groups * m.tokens_per_item + 8))

    def payload(image_seed: int) -> dict:
        rng = np.random.default_rng(image_seed)
        emb = (rng.standard_normal((n_groups * m.tokens_per_item,
                                    m.enc_d_model))
               .astype(np.float32) * 0.1)
        return {"messages": [{"role": "user", "content": [
                    {"type": "text", "text": text},
                    {"type": "image_embedding", "embedding": emb.tolist()}]}],
                "max_tokens": 4}

    eng = EPDEngine(cfg, params, EngineConfig(
        n_encode_workers=2, decode_batch=4, kv_blocks=128, max_seq_len=256))
    eng.start()
    # warm-up on a DIFFERENT image: compiles E/P/D outside the window
    chat_completion(eng, payload(0), timeout=600)
    first = chat_completion(eng, payload(1), timeout=600)
    shards_first_seen = eng.encode_stage.shards_run
    repeat = chat_completion(eng, payload(1), timeout=600)
    eng.stop()
    out = {
        "ttft_first": first["timings"]["ttft"],
        "ttft_repeat": repeat["timings"]["ttft"],
        "repeat_hit": repeat["timings"]["mm_cache_hit"],
        "cache_hits": eng.mm_cache.hits,
        "cache_misses": eng.mm_cache.misses,
        "encode_shards_after_repeat": eng.encode_stage.shards_run,
        "encode_shards_first_seen": shards_first_seen,
    }
    _ENGINE_MM_CACHE[key] = out
    return out


def engine_prefix_cache_stats(quick: bool = False,
                              arch: str = "codeqwen1.5-7b") -> dict:
    """Block-level KV prefix caching on a chat-shaped text workload: a
    64-token shared system prompt across user turns, a turn-2 prompt
    extending turn 1's full transcript, and an exact multi-turn repeat.
    Runs the engine cache-off then cache-on and reports per-phase TTFT
    plus the prefill chunk/token deltas — the on-run must plan strictly
    fewer prefill rows (ZERO for the block-aligned exact repeat)."""
    key = (quick, arch)
    if key in _ENGINE_PREFIX_CACHE:
        return _ENGINE_PREFIX_CACHE[key]
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import EPDEngine, EngineConfig, ServeRequest

    cfg = get_config(arch).reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(17)
    sys_prompt = rng.integers(0, cfg.vocab, 64).astype(np.int32)
    n_users = 2 if quick else 4
    users = [rng.integers(0, cfg.vocab, 16).astype(np.int32)
             for _ in range(n_users)]
    max_new = 8
    # turn 1: system prompt + first user message (80 tokens = 5 full
    # blocks, so the exact repeat is FULLY cached when the cache is on)
    turn1 = np.concatenate([sys_prompt, users[0]])

    out = {}
    for on in (False, True):
        eng = EPDEngine(cfg, params, EngineConfig(
            decode_batch=2, kv_blocks=128, max_seq_len=256,
            prefill_chunk=32, prefix_cache=on))
        eng.start()
        try:
            # warm-up: compiles prefill/decode AND seeds the cache with
            # the system prompt, outside the measured window
            eng.submit(ServeRequest(req_id=1, prompt=turn1.copy(),
                                    max_new_tokens=max_new))
            r_first = eng.result(1, timeout=600)
            s0 = dict(eng.stats)
            t0 = time.perf_counter()
            rid, shared_ttfts = 2, []
            for u in users:
                eng.submit(ServeRequest(
                    req_id=rid, prompt=np.concatenate([sys_prompt, u]),
                    max_new_tokens=max_new))
                shared_ttfts.append(eng.result(rid, timeout=600).ttft)
                rid += 1
            # multi-turn: turn 2 extends turn 1's full transcript
            turn2 = np.concatenate([
                turn1, np.asarray(r_first.tokens, np.int32),
                rng.integers(0, cfg.vocab, 16).astype(np.int32)])
            eng.submit(ServeRequest(req_id=rid, prompt=turn2,
                                    max_new_tokens=max_new))
            r_turn2 = eng.result(rid, timeout=600)
            rid += 1
            # exact repeat of turn 1: fully cached -> zero prefill rows
            eng.submit(ServeRequest(req_id=rid, prompt=turn1.copy(),
                                    max_new_tokens=max_new))
            r_repeat = eng.result(rid, timeout=600)
            wall = time.perf_counter() - t0
            s1 = dict(eng.stats)
        finally:
            eng.stop()
        out["on" if on else "off"] = {
            "mean_shared_ttft": float(np.mean(shared_ttfts)),
            "multi_turn_ttft": r_turn2.ttft,
            "repeat_ttft": r_repeat.ttft,
            "prefill_chunks": s1["prefill_chunks"] - s0["prefill_chunks"],
            "prefill_tokens": (s1["packed_prefill_tokens"]
                               - s0["packed_prefill_tokens"]),
            "prefix_tokens_reused": (s1["prefix_tokens_reused"]
                                     - s0["prefix_tokens_reused"]),
            "prefix_cache_hits": (s1["prefix_cache_hits"]
                                  - s0["prefix_cache_hits"]),
            "wall_s": wall,
            "n_requests": n_users + 2,
        }
    _ENGINE_PREFIX_CACHE[key] = out
    return out


_ENGINE_OVERLAP_CACHE: dict = {}


def engine_overlap_stats(quick: bool = False,
                         arch: str = "pixtral-12b") -> dict:
    """Encode–prefill overlap + packed encode lanes on a many-image
    request: a text prefix followed by the image placeholders, so with
    overlap ON the prefix prefill chunks are admitted while the IRP
    shards are still encoding, and the lane path folds the per-shard
    dispatch/handoff tail into packed steps that run anyway. Off vs on,
    same reduced model, byte-identical requests (tokens asserted equal).
    ``min_ttft`` is the headline statistic — on a noisy shared host the
    per-arm floor is the faithful critical-path estimate, and the win it
    shows is the hidden encode tail."""
    key = (quick, arch)
    if key in _ENGINE_OVERLAP_CACHE:
        return _ENGINE_OVERLAP_CACHE[key]
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import EPDEngine, EngineConfig, ServeRequest

    cfg = get_config(arch).reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    m = cfg.modality
    n_groups = 8 if quick else 12                # "many images"
    M = n_groups * m.tokens_per_item
    prefix = 96 if quick else 160                # text before the images
    n_req = 6 if quick else 8

    def request(req_id: int) -> ServeRequest:
        rng = np.random.default_rng(7 + req_id % 100)
        S = prefix + M + 8
        return ServeRequest(
            req_id=req_id,
            prompt=rng.integers(0, cfg.vocab, S).astype(np.int32),
            mm_embeds=(rng.standard_normal((M, m.enc_d_model))
                       .astype(np.float32) * 0.1),
            mm_positions=np.arange(prefix, prefix + M, dtype=np.int32),
            max_new_tokens=4)

    out = {}
    tokens = {}
    for name, overlap in (("off", False), ("on", True)):
        kw = dict(encode_overlap=True, encode_lanes=True) if overlap else {}
        eng = EPDEngine(cfg, params, EngineConfig(
            n_encode_workers=4, decode_batch=2, prefill_chunk=32,
            kv_blocks=128, max_seq_len=512, **kw))
        eng.start()
        # warm-up compiles E/P/D outside the measured window
        eng.submit(request(1000 + 99)).result(timeout=600)
        ttfts, toks = [], []
        t0 = time.perf_counter()
        for i in range(n_req):
            r = request((2000 if overlap else 1000) + i)
            res = eng.submit(r).result(timeout=600)
            ttfts.append(r.t_first_token - r.t_submit)
            toks.append(list(res.tokens))
        wall = time.perf_counter() - t0
        eng.stop()
        tokens[name] = toks
        out[name] = {
            "min_ttft": float(np.min(ttfts)),
            "mean_ttft": float(np.mean(ttfts)),
            "median_ttft": float(np.median(ttfts)),
            "overlap_chunks_early": eng.stats["overlap_chunks_early"],
            "overlap_watermark_hwm": eng.stats["overlap_watermark_hwm"],
            "encode_lane_rows": eng.stats["encode_lane_rows"],
            "wall_s": wall,
            "n_requests": n_req,
        }
    out["bit_identical"] = tokens["on"] == tokens["off"]
    _ENGINE_OVERLAP_CACHE[key] = out
    return out


# Paper SLO criteria (Table 9)
SLO_TABLE9 = {
    ("minicpm-v-2.6", 2): (1.40, 0.04), ("minicpm-v-2.6", 4): (2.60, 0.04),
    ("minicpm-v-2.6", 6): (3.90, 0.06), ("minicpm-v-2.6", 8): (5.10, 0.06),
    ("internvl2-8b", 2): (1.20, 0.05), ("internvl2-8b", 4): (2.40, 0.06),
    ("internvl2-8b", 6): (3.55, 0.09), ("internvl2-8b", 8): (5.00, 0.18),
    ("internvl2-26b", 2): (3.50, 0.07), ("internvl2-26b", 4): (7.05, 0.08),
    ("internvl2-26b", 6): (11.00, 0.95), ("internvl2-26b", 8): (15.00, 0.15),
}

EPD_SPEC = "5E2P1D"
DIST_SPEC = "7EP1D"
VLLM_SPEC = "8EPD"
