"""Figure 10 + Appendix A.3: offline E2E throughput.

Left: vary #encode workers (x->y->0 notation: x E, y P workers; decode on
1); DistServe fixed 7P(EP)1D. Middle: throughput vs images/request.
Right: sensitivity to encode/prefill batch size.
1000 single-image requests, 10 output tokens (quick: 200).
"""
from __future__ import annotations

if __package__ in (None, ""):
    # running as a script (python benchmarks/offline_throughput.py): put the
    # repo root and src/ on sys.path so `benchmarks.common` and `repro`
    # resolve without an external PYTHONPATH
    import os
    import sys
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)

from repro.configs import get_config
from repro.core import A100_80G
from repro.core.cluster import ClusterSpec, simulate
from repro.data.workload import WorkloadSpec, poisson_requests

from benchmarks.common import (Row, engine_mm_cache_stats, engine_mode_stats,
                               timed)

CFG = get_config("minicpm-v-2.6")


def _throughput(spec: ClusterSpec, reqs) -> float:
    out = simulate(spec, CFG, A100_80G, reqs)
    makespan = max(r.finish for r in out) - min(r.arrival for r in out)
    return len(out) / makespan


def _offline_requests(n, n_items=1):
    # all submitted up-front (offline batch) ~ huge rate
    return poisson_requests(CFG, WorkloadSpec(
        rate=1e6, n_requests=n, n_items=n_items, output_len=10,
        resolution=(787, 444)))


def run(quick: bool = False) -> list[Row]:
    rows: list[Row] = []
    n = 200 if quick else 1000
    reqs = _offline_requests(n)
    # left plot: x E, y P
    for n_e, n_p in ((2, 5), (3, 4), (4, 3), (5, 2), (6, 1)):
        spec = ClusterSpec(f"{n_e}E{n_p}P1D", max_batch=8, decode_batch=128)
        thr, us = timed(_throughput, spec, reqs)
        rows.append(Row(f"fig10_left/{n_e}E{n_p}P1D", us, round(thr, 2)))
    thr, us = timed(_throughput,
                    ClusterSpec("7EP1D", irp=False, max_batch=1,
                                decode_batch=128), reqs)
    rows.append(Row("fig10_left/distserve_7EP1D_b1", us, round(thr, 2)))
    # middle: images per request
    for n_items in (1, 2, 4) if quick else (1, 2, 4, 8):
        r2 = _offline_requests(n // 2, n_items=n_items)
        epd = _throughput(ClusterSpec("5E2P1D", max_batch=8,
                                      decode_batch=128), r2)
        dist = _throughput(ClusterSpec("7EP1D", irp=False, max_batch=1,
                                       decode_batch=128), r2)
        rows.append(Row(f"fig10_mid/img{n_items}", 0.0,
                        f"epd={epd:.2f};dist={dist:.2f}"))
    # right: batch-size sensitivity
    for b in (1, 2, 8, 32):
        thr = _throughput(ClusterSpec("5E2P1D", max_batch=b,
                                      decode_batch=128), reqs)
        rows.append(Row(f"fig10_right/batch{b}", 0.0, round(thr, 2)))
    rows.extend(run_heterogeneous(quick))
    rows.extend(run_engine_modes(quick))
    return rows


def run_engine_modes(quick: bool = False) -> list[Row]:
    """Real-execution decode-stage comparison: paged-batched (one jitted
    step over shared KVBlockManager pool blocks) vs the seed dense
    per-request loop — decode tokens/s and peak KV-cache bytes."""
    stats = engine_mode_stats(quick)
    rows = []
    for mode in ("paged", "dense"):
        s = stats[mode]
        rows.append(Row(f"engine/{mode}/decode_tok_s", s["wall_s"] * 1e6,
                        round(s["decode_tok_s"], 1),
                        {"decode_steps": s["decode_steps"],
                         "n_requests": s["n_requests"]}))
        rows.append(Row(f"engine/{mode}/peak_cache_bytes", 0.0,
                        s["peak_cache_bytes"]))
    rows.append(Row("engine/paged_over_dense_tok_s", 0.0,
                    round(stats["paged"]["decode_tok_s"]
                          / max(stats["dense"]["decode_tok_s"], 1e-9), 2)))
    rows.append(Row("engine/dense_over_paged_cache_bytes", 0.0,
                    round(stats["dense"]["peak_cache_bytes"]
                          / max(stats["paged"]["peak_cache_bytes"], 1), 2)))
    mm = engine_mm_cache_stats(quick)
    rows.append(Row("engine/mm_cache_hit_ttft_speedup", 0.0,
                    round(mm["ttft_first"] / max(mm["ttft_repeat"], 1e-9), 2),
                    {"first_seen_ttft": round(mm["ttft_first"], 4),
                     "repeat_ttft": round(mm["ttft_repeat"], 4),
                     "mm_cache_hit": mm["repeat_hit"]}))
    return rows


def run_heterogeneous(quick: bool = False) -> list[Row]:
    """App A.3 heterogeneous setting: a cluster mixing high-end and
    low-memory devices. The aggregated EP worker cannot even hold encoder +
    LLM + KV on the low-end card (OOM -> effectively batch 1 / infeasible),
    while EPD places E stages on the small devices and P/D on the big ones."""
    from dataclasses import replace as _replace
    lowend = _replace(A100_80G, name="a30-24g", mem_bytes=24e9,
                      peak_flops=165e12, hbm_bw=933e9)
    n = 100 if quick else 400
    reqs = _offline_requests(n)
    rows = []
    # EPD: 5 low-end E + 2 big P + 1 big D
    epd = ClusterSpec("5E2P1D", max_batch=8, decode_batch=128,
                      hw_mix=[lowend] * 5 + [A100_80G] * 3)
    thr, us = timed(_throughput, epd, reqs)
    rows.append(Row("appA3_hetero/EPD_lowendE", us, round(thr, 2)))
    # DistServe: EP on the SAME mix — low-end EP workers are memory-starved
    # (batch 1), big ones fine
    dist = ClusterSpec("7EP1D", irp=False, max_batch=1, decode_batch=128,
                       hw_mix=[lowend] * 5 + [A100_80G] * 3)
    thr_d, us_d = timed(_throughput, dist, reqs)
    rows.append(Row("appA3_hetero/DistServe_mixed_b1", us_d, round(thr_d, 2)))
    rows.append(Row("appA3_hetero/epd_over_dist", 0.0,
                    round(thr / max(thr_d, 1e-9), 2)))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    print("name,us_per_call,derived")
    for row in run(quick=ap.parse_args().quick):
        print(row.csv(), flush=True)
