"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run [--quick] [--only MODULE]`` prints one CSV line
``name,us_per_call,derived`` per measurement and writes the full records
(with paper reference values) to runs/bench_results.json.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

MODULES = [
    "slo_attainment",      # Fig 5 / Fig 11
    "ttft",                # Fig 6 / §4.2
    "real_traces",         # Fig 7 / Fig 8
    "video_ttft",          # Table 1
    "memory_tables",       # §4.3, Tables 2, 3, 8
    "ablations",           # Tables 4, 5, 6
    "offline_throughput",  # Fig 10 / App A.3
    "audio_npu",           # Table 7, Fig 9, Fig 12 / App A.1, F
    "roofline",            # dry-run roofline report (deliverable g)
    "kernel_bench",        # kernel oracle micro-times
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="runs/bench_results.json")
    args = ap.parse_args()

    mods = [args.only] if args.only else MODULES
    all_rows = []
    print("name,us_per_call,derived")
    for name in mods:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            rows = mod.run(quick=args.quick)
        except Exception as e:  # keep the harness going; report the failure
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}", flush=True)
            continue
        for row in rows:
            print(row.csv(), flush=True)
            all_rows.append({"name": row.name, "us_per_call": row.us_per_call,
                             "derived": row.derived, **row.extra})
        print(f"# {name}: {len(rows)} rows in {time.time()-t0:.1f}s",
              file=sys.stderr)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(all_rows, f, indent=1, default=str)


if __name__ == "__main__":
    main()
